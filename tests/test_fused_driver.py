"""Fused `lax.scan` driver vs the host loop (DESIGN.md §2).

The two drivers consume randomness through the identical split chain, so on
one backend they should agree exactly (up to XLA float reassociation flipping
rare near-ties); the statistical tests below are robust to those flips while
still failing loudly on any systematic divergence.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MWEMConfig, run_mwem, run_mwem_batch, run_mwem_fused
from repro.core.accountant import PrivacyLedger
from repro.core.queries import gaussian_histogram, max_error, random_binary_queries
from repro.mips import FlatAbsIndex, NSWIndex, augment_complement


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    U, m, n = 64, 128, 300
    h = gaussian_histogram(kh, n, U)
    Q = random_binary_queries(kq, m, U)
    return Q, h, n


@pytest.fixture(scope="module")
def index(workload):
    Q, _, _ = workload
    return FlatAbsIndex(Q)


def _tv(p, q):
    return 0.5 * np.abs(np.asarray(p) - np.asarray(q)).sum()


class TestEquivalence:
    def test_routing(self, workload, index):
        Q, h, n = workload
        aug = augment_complement(np.asarray(Q))
        nsw = NSWIndex(aug, deg=8, ef=16, rounds=2, seed=0)
        from repro.core.mwem import _resolve_driver

        assert _resolve_driver(MWEMConfig(n_records=n), index) == "fused"
        # NSW's fixed-shape beam search traces since the megakernel PR —
        # auto-routing sends it through the fused scan like every other
        # built-in index (host remains available explicitly)
        assert _resolve_driver(MWEMConfig(n_records=n), nsw) == "fused"
        assert _resolve_driver(MWEMConfig(mode="exact", n_records=n), None) == "fused"
        res = run_mwem(Q, h, MWEMConfig(T=4, n_records=n, driver="fused"),
                       jax.random.PRNGKey(0), index=nsw)
        assert len(res.selected) == 4

        class HostOnly:
            supports_in_graph = False
            approx_margin = 0.0
            failure_mass = 0.0

        assert _resolve_driver(MWEMConfig(n_records=n), HostOnly()) == "host"
        with pytest.raises(ValueError, match="host"):
            run_mwem(Q, h, MWEMConfig(n_records=n, driver="fused"),
                     jax.random.PRNGKey(0), index=HostOnly())

    def test_selection_distributions_match(self, workload, index):
        """TV distance between fused and host-loop selection frequencies
        over many seeds is tiny (they share the PRNG chain)."""
        Q, h, n = workload
        m = Q.shape[0]
        T, B = 6, 25
        cfg = MWEMConfig(T=T, mode="fast", n_records=n)
        cfg_host = MWEMConfig(T=T, mode="fast", n_records=n, driver="host")
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
        fused = run_mwem_batch(Q, h, cfg, keys, index=index)
        host_sel = []
        for s in range(B):
            host_sel.extend(
                run_mwem(Q, h, cfg_host, jax.random.PRNGKey(s), index=index).selected)
        f = np.bincount(fused.selected.ravel(), minlength=m) / (B * T)
        g = np.bincount(np.asarray(host_sel), minlength=m) / (B * T)
        assert _tv(f, g) < 0.1

    def test_identical_ledger_totals(self, workload, index):
        Q, h, n = workload
        for mode, idx in (("fast", index), ("exact", None)):
            cfg = MWEMConfig(eps=1.0, delta=1e-3, T=16, mode=mode, n_records=n)
            cfg_host = MWEMConfig(eps=1.0, delta=1e-3, T=16, mode=mode,
                                  n_records=n, driver="host")
            rf = run_mwem(Q, h, cfg, jax.random.PRNGKey(5), index=idx)
            rh = run_mwem(Q, h, cfg_host, jax.random.PRNGKey(5), index=idx)
            assert rf.ledger.composed() == rh.ledger.composed()
            assert rf.ledger.basic() == rh.ledger.basic()
            assert len(rf.ledger.events) == len(rh.ledger.events)

    def test_fused_error_tracks_host(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(T=60, mode="fast", n_records=n)
        cfg_host = MWEMConfig(T=60, mode="fast", n_records=n, driver="host")
        rf = run_mwem(Q, h, cfg, jax.random.PRNGKey(7), index=index)
        rh = run_mwem(Q, h, cfg_host, jax.random.PRNGKey(7), index=index)
        assert abs(rf.final_error - rh.final_error) < 0.05
        uniform = float(max_error(Q, h, jnp.full_like(h, 1 / h.shape[0])))
        assert rf.final_error < uniform

    def test_eval_every_trace(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(T=20, mode="fast", eval_every=5, n_records=n)
        res = run_mwem(Q, h, cfg, jax.random.PRNGKey(6), index=index)
        assert [t for t, _ in res.errors] == [5, 10, 15, 20]
        assert all(np.isfinite(e) for _, e in res.errors)


class TestOverflowFallback:
    def test_tiny_tail_cap_falls_back_in_graph(self, workload, index):
        """tail_cap=1 forces C > cap almost every step; the in-graph
        `lax.cond` fallback must reproduce the host loop's exhaustive redo."""
        Q, h, n = workload
        cfg = MWEMConfig(T=12, mode="fast", n_records=n, tail_cap=1)
        cfg_host = MWEMConfig(T=12, mode="fast", n_records=n, tail_cap=1,
                              driver="host")
        rf = run_mwem(Q, h, cfg, jax.random.PRNGKey(3), index=index)
        rh = run_mwem(Q, h, cfg_host, jax.random.PRNGKey(3), index=index)
        assert rf.overflow_count > 0
        assert rf.overflow_count == rh.overflow_count
        m = Q.shape[0]
        assert all(0 <= sel < m for sel in rf.selected)
        # fallback iterations score all m candidates, lazy ones ≤ k+1
        assert sum(s == m for s in rf.n_scored) == rf.overflow_count
        assert rf.n_scored == rh.n_scored
        assert np.isfinite(rf.final_error)

    def test_no_overflow_with_default_cap(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(T=30, mode="fast", n_records=n)
        res = run_mwem(Q, h, cfg, jax.random.PRNGKey(4), index=index)
        assert res.overflow_count == 0
        # sublinear scoring: mean evaluations well below m
        assert np.mean(res.n_scored) < Q.shape[0] * 0.9


class TestAbsTopKKernel:
    def test_matches_jnp_abs_path(self):
        """`mips_abs_topk` (one streaming pass merging both signs) returns
        the same augmented-id top-k as the jnp abs path."""
        from repro.kernels.mips_topk import mips_abs_topk

        Q = jax.random.uniform(jax.random.PRNGKey(0), (200, 64))
        v = jax.random.normal(jax.random.PRNGKey(1), (64,))
        k = 15
        aug_k, s_k = mips_abs_topk(Q, v, k, block_n=64, block_d=32,
                                   interpret=True)
        aug_j, s_j = FlatAbsIndex(Q).query(v, k)
        assert set(np.asarray(aug_k).tolist()) == set(np.asarray(aug_j).tolist())
        np.testing.assert_allclose(np.sort(np.asarray(s_k)),
                                   np.sort(np.asarray(s_j)), atol=1e-5)


@pytest.fixture(scope="module")
def ivf_indices(workload):
    """The same IVF structure under both probe routes: XLA gather vs the
    fused Pallas kernel (interpret mode on CPU)."""
    from repro.mips import IVFIndex

    Q, _, _ = workload
    aug = augment_complement(np.asarray(Q))
    return (IVFIndex(aug, seed=0, train_iters=3, use_pallas="never"),
            IVFIndex(aug, seed=0, train_iters=3, use_pallas="always"))


class TestKernelizedProbe:
    """DESIGN.md §3: swapping the kernelized IVF probe into the fused scan
    must leave the driver's traces unchanged."""

    def test_fused_traces_unchanged(self, workload, ivf_indices):
        Q, h, n = workload
        ivf_xla, ivf_ker = ivf_indices
        cfg = MWEMConfig(T=6, mode="fast", n_records=n)
        rx = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(2), index=ivf_xla)
        rk = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(2), index=ivf_ker)
        assert rx.selected == rk.selected
        assert rx.n_scored == rk.n_scored
        assert rx.overflow_count == rk.overflow_count
        assert abs(rx.final_error - rk.final_error) < 1e-5

    def test_waved_batch_matches_singles(self, workload, ivf_indices):
        """`run_mwem_batch` routes batch-probe indices through the waved
        scan core (one probe call per iteration for all lanes); every lane
        must reproduce its standalone fused run exactly."""
        ivf_xla, _ = ivf_indices
        Q, h, n = workload
        B = 3
        cfg = MWEMConfig(T=6, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
        batch = run_mwem_batch(Q, h, cfg, keys, index=ivf_xla)
        for b in range(B):
            single = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(b),
                                    index=ivf_xla)
            assert list(batch.selected[b]) == single.selected
            assert list(batch.n_scored[b]) == single.n_scored

    def test_waved_batch_kernel_route(self, workload, ivf_indices):
        """The Pallas batch kernel route agrees with the XLA waved route
        (away from exact ties both orderings retrieve the same set)."""
        ivf_xla, ivf_ker = ivf_indices
        Q, h, n = workload
        cfg = MWEMConfig(T=5, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2)])
        bx = run_mwem_batch(Q, h, cfg, keys, index=ivf_xla)
        bk = run_mwem_batch(Q, h, cfg, keys, index=ivf_ker)
        assert np.array_equal(bx.selected, bk.selected)
        np.testing.assert_allclose(np.asarray(bx.final_errors),
                                   np.asarray(bk.final_errors), atol=1e-5)

    def test_waved_eval_every_matches_single(self, workload, ivf_indices):
        ivf_xla, _ = ivf_indices
        Q, h, n = workload
        cfg = MWEMConfig(T=6, mode="fast", n_records=n, eval_every=3)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2)])
        batch = run_mwem_batch(Q, h, cfg, keys, index=ivf_xla)
        single = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(1),
                                index=ivf_xla)
        lane = batch.unbatch()[1].errors
        assert [t for t, _ in lane] == [t for t, _ in single.errors]
        np.testing.assert_allclose([e for _, e in lane],
                                   [e for _, e in single.errors], atol=1e-5)


class TestBatch:
    def test_shapes_and_determinism(self, workload, index):
        Q, h, n = workload
        U, m = h.shape[0], Q.shape[0]
        B, T = 5, 8
        cfg = MWEMConfig(T=T, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
        r1 = run_mwem_batch(Q, h, cfg, keys, index=index)
        r2 = run_mwem_batch(Q, h, cfg, keys, index=index)
        assert r1.p_hat.shape == (B, U)
        assert r1.selected.shape == (B, T)
        assert r1.n_scored.shape == (B, T)
        assert r1.final_errors.shape == (B,)
        assert np.array_equal(r1.selected, r2.selected)
        assert np.allclose(np.asarray(r1.p_hat), np.asarray(r2.p_hat))

    def test_batch_lane_matches_single_run(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(T=8, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(3)])
        batch = run_mwem_batch(Q, h, cfg, keys, index=index)
        single = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(1), index=index)
        assert list(batch.selected[1]) == single.selected
        assert abs(float(batch.final_errors[1]) - single.final_error) < 1e-4

    def test_batched_histograms(self, workload, index):
        Q, h, n = workload
        B = 3
        cfg = MWEMConfig(T=6, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
        hb = jnp.stack([h] * B)
        shared = run_mwem_batch(Q, h, cfg, keys, index=index)
        per = run_mwem_batch(Q, hb, cfg, keys, index=index)
        assert np.array_equal(shared.selected, per.selected)
        assert np.allclose(shared.final_errors, per.final_errors, atol=1e-5)

    def test_host_driver_rejected(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(T=4, mode="fast", n_records=n, driver="host")
        keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
        with pytest.raises(ValueError, match="fused driver"):
            run_mwem_batch(Q, h, cfg, keys, index=index)

    def test_eval_every_trace_matches_single(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(T=10, mode="fast", eval_every=5, n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2)])
        batch = run_mwem_batch(Q, h, cfg, keys, index=index)
        single = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(1), index=index)
        assert batch.errors.shape == (2, 2)
        lane = batch.unbatch()[1].errors
        assert [t for t, _ in lane] == [t for t, _ in single.errors]
        np.testing.assert_allclose([e for _, e in lane],
                                   [e for _, e in single.errors], atol=1e-5)

    def test_unbatch(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(T=6, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2)])
        batch = run_mwem_batch(Q, h, cfg, keys, index=index)
        results = batch.unbatch()
        assert len(results) == 2
        for b, res in enumerate(results):
            assert res.selected == list(batch.selected[b])
            assert res.p_hat.shape == h.shape
            assert np.isfinite(res.final_error)

    def test_unbatch_full_trace_fields(self, workload, index):
        """unbatch() must reproduce every per-lane trace field of a
        standalone fused run — n_scored, overflow_count, honest timing
        via the telemetry record, and the shared-ledger default."""
        Q, h, n = workload
        B, T = 3, 8
        cfg = MWEMConfig(T=T, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
        batch = run_mwem_batch(Q, h, cfg, keys, index=index)
        results = batch.unbatch()
        single = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(2), index=index)
        assert results[2].selected == single.selected
        assert results[2].n_scored == single.n_scored
        assert results[2].overflow_count == single.overflow_count
        np.testing.assert_allclose(np.asarray(results[2].p_hat),
                                   np.asarray(single.p_hat), atol=1e-6)
        for b, res in enumerate(results):
            # a lane has no per-iteration wall clock of its own — unbatch
            # refuses to fabricate one (it used to hand out total/T per lane)
            assert res.iter_seconds == []
            assert res.telemetry is not None
            assert res.telemetry.amortized
            assert res.telemetry.total_seconds == pytest.approx(
                batch.total_seconds, rel=1e-9)
            assert res.telemetry.lanes == 1
            assert res.telemetry.T == T
            assert res.telemetry.overflow_count == res.overflow_count
            assert res.telemetry.n_scored_total == sum(res.n_scored)
            assert res.ledger is batch.ledger  # shared per-run ledger
        # the batch record itself covers all lanes
        assert batch.telemetry.lanes == B
        assert batch.telemetry.n_scored_total == int(
            np.asarray(batch.n_scored).sum())


class TestBatchLedgerContract:
    """DESIGN.md §2 'Batched replication': the result ledger is per *run*;
    releasing B replicas composes B× the budget — the caller's contract,
    asserted here, and discharged by the per-lane `ledgers` plumbing."""

    def test_per_run_ledger_equals_single_run(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(eps=1.0, delta=1e-3, T=10, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(4)])
        batch = run_mwem_batch(Q, h, cfg, keys, index=index)
        single = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(0), index=index)
        # the batch ledger records ONE run's events, not 4×
        assert batch.ledger.composed() == single.ledger.composed()
        assert batch.ledger.basic() == single.ledger.basic()
        assert len(batch.ledger.events) == len(single.ledger.events)

    def test_b_replica_composition_is_b_times(self, workload, index):
        """Charging one consumer ledger for all B lanes composes exactly
        B× the per-run event multiset (B× under basic composition; the
        √B-ish advanced-composition total matches an explicit preview)."""
        Q, h, n = workload
        B = 3
        cfg = MWEMConfig(eps=1.0, delta=1e-3, T=10, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
        consumer = PrivacyLedger()
        batch = run_mwem_batch(Q, h, cfg, keys, index=index,
                               ledgers=[consumer] * B)
        per_run = batch.ledger
        assert len(consumer.events) == B * len(per_run.events)
        eps_b, delta_b = consumer.basic()
        eps_1, delta_1 = per_run.basic()
        assert eps_b == pytest.approx(B * eps_1, rel=1e-12)
        assert delta_b == pytest.approx(B * delta_1, rel=1e-12)
        # advanced composition of the B× multiset, cross-checked via preview
        expected = PrivacyLedger().preview(
            list(per_run.events) * B,
            gamma=B * per_run.index_failure_mass,
            slack=B * per_run.approx_slack)
        assert consumer.composed() == expected

    def test_per_lane_ledgers_reach_unbatch(self, workload, index):
        Q, h, n = workload
        B = 3
        cfg = MWEMConfig(T=6, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
        lanes = [PrivacyLedger(), None, PrivacyLedger()]
        batch = run_mwem_batch(Q, h, cfg, keys, index=index, ledgers=lanes)
        for lane in (lanes[0], lanes[2]):
            assert lane.composed() == batch.ledger.composed()
        results = batch.unbatch()
        assert results[0].ledger is lanes[0]
        assert results[1].ledger is None  # skipped lane carries no ledger
        assert results[2].ledger is lanes[2]

    def test_ledgers_length_mismatch_raises(self, workload, index):
        Q, h, n = workload
        cfg = MWEMConfig(T=4, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2)])
        with pytest.raises(ValueError, match="one entry per lane"):
            run_mwem_batch(Q, h, cfg, keys, index=index,
                           ledgers=[PrivacyLedger()])
