"""Blockwise (XLA-flash) attention vs the reference, across mask modes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.ref import attention_ref
from repro.models.attention import blockwise_attention


class TestBlockwise:
    @pytest.mark.parametrize("mode,window", [
        ("full", 0), ("causal", 0), ("window", 24), ("chunk", 32)])
    def test_matches_ref(self, mode, window):
        rng = np.random.default_rng(0)
        B, Hq, Hkv, S, D = 2, 4, 2, 150, 16
        q = jnp.asarray(rng.standard_normal((B, Hq, S, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B, Hkv, S, D)), jnp.float32)
        out_b = blockwise_attention(q, k, v, mode=mode, window=window, chunk=32)
        out_r = attention_ref(q, k, v, mode=mode, window=window)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_r),
                                   rtol=2e-4, atol=2e-4)

    @given(b=st.integers(1, 2), hkv=st.integers(1, 2), g=st.integers(1, 3),
           s=st.integers(2, 100), d=st.integers(4, 16),
           chunk=st.sampled_from([16, 32, 64]), seed=st.integers(0, 100))
    @settings(max_examples=15, deadline=None)
    def test_shape_sweep_causal(self, b, hkv, g, s, d, chunk, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((b, hkv * g, s, d)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, hkv, s, d)), jnp.float32)
        out_b = blockwise_attention(q, k, v, mode="causal", chunk=chunk)
        out_r = attention_ref(q, k, v, mode="causal")
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_r),
                                   rtol=3e-4, atol=3e-4)

    def test_softcap(self):
        rng = np.random.default_rng(1)
        q = jnp.asarray(rng.standard_normal((1, 2, 50, 8)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, 50, 8)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, 50, 8)), jnp.float32)
        out_b = blockwise_attention(q, k, v, mode="causal", logit_softcap=10.0,
                                    chunk=16)
        out_r = attention_ref(q, k, v, mode="causal", logit_softcap=10.0)
        np.testing.assert_allclose(np.asarray(out_b), np.asarray(out_r),
                                   rtol=3e-4, atol=3e-4)
