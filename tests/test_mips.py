"""Recall / exactness tests for the k-MIPS substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.mips import (
    FlatIndex, FlatAbsIndex, IVFIndex, LSHIndex, NSWIndex,
    augment_complement, build_index,
)
from repro.mips.transform import mips_to_knn_keys, mips_to_knn_query


def _make_data(n=512, dim=32, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.standard_normal((n, dim)).astype(np.float32)
    q = rng.standard_normal((dim,)).astype(np.float32)
    return V, q


def _recall(idx, V, q, k):
    truth = np.argsort(-(V @ q))[:k]
    return len(set(np.asarray(idx).tolist()) & set(truth.tolist())) / k


class TestTransform:
    @given(st.integers(2, 50), st.integers(2, 16), st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_preserves_inner_products_and_norms(self, n, dim, seed):
        rng = np.random.default_rng(seed)
        V = rng.standard_normal((n, dim)).astype(np.float32)
        q = rng.standard_normal((dim,)).astype(np.float32)
        Vt, M = mips_to_knn_keys(V)
        qt = mips_to_knn_query(q)
        np.testing.assert_allclose(Vt @ qt, V @ q, rtol=1e-5, atol=1e-5)
        norms = np.linalg.norm(Vt, axis=1)
        np.testing.assert_allclose(norms, M, rtol=1e-4)


class TestFlat:
    def test_exact(self):
        V, q = _make_data()
        idx, scores = FlatIndex(V, use_pallas="never").query(q, 10)
        assert _recall(idx, V, q, 10) == 1.0
        np.testing.assert_allclose(np.asarray(scores), np.sort(V @ q)[::-1][:10],
                                   rtol=1e-5)

    def test_flat_abs_matches_augmented(self):
        rng = np.random.default_rng(1)
        Q = rng.uniform(0, 1, size=(100, 16)).astype(np.float32)
        v = rng.standard_normal(16).astype(np.float32)
        v = v - v.mean()  # Σv = 0 — the histogram-difference regime
        aug = augment_complement(Q)
        idx_a, s_a = FlatIndex(aug, use_pallas="never").query(v, 7)
        idx_b, s_b = FlatAbsIndex(Q).query(v, 7)
        np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b), rtol=2e-4, atol=2e-5)
        assert set(np.asarray(idx_a).tolist()) == set(np.asarray(idx_b).tolist())


class TestIVF:
    def test_high_recall_on_clustered_data(self):
        rng = np.random.default_rng(0)
        centers = rng.standard_normal((16, 24)) * 4
        V = (centers[rng.integers(0, 16, 2048)] +
             rng.standard_normal((2048, 24)) * 0.3).astype(np.float32)
        q = V[3] + rng.standard_normal(24).astype(np.float32) * 0.05
        ix = IVFIndex(V, seed=0)
        idx, _ = ix.query(q, 10)
        assert _recall(idx, V, q, 10) >= 0.5
        assert ix.query_cost(10) < V.shape[0]

    def test_valid_ids_and_sorted_scores(self):
        V, q = _make_data(700, 24, 2)
        ix = IVFIndex(V, seed=1)
        idx, scores = ix.query(q, 16)
        s = np.asarray(scores)
        assert np.all(np.diff(s) <= 1e-6)
        assert np.all(np.asarray(idx) >= 0) and np.all(np.asarray(idx) < 700)


class TestLSH:
    def test_reasonable_recall(self):
        V, q = _make_data(1024, 32, 3)
        # make the true top item easy: plant a near-duplicate of the query
        V[0] = q * 3.0
        ix = LSHIndex(V, n_tables=16, seed=0)
        idx, _ = ix.query(q, 8)
        assert 0 in np.asarray(idx).tolist()


class TestNSW:
    def test_recall_against_exact(self):
        V, q = _make_data(2048, 32, 4)
        ix = NSWIndex(V, deg=16, ef=48, rounds=5, seed=0)
        idx, _ = ix.query(q, 10)
        assert _recall(idx, V, q, 10) >= 0.6

    def test_tiny_dataset(self):
        V, q = _make_data(10, 8, 5)
        ix = NSWIndex(V, deg=4, ef=8, rounds=2, seed=0)
        idx, scores = ix.query(q, 3)
        assert _recall(idx, V, q, 3) == 1.0


class TestFactory:
    def test_build_index(self):
        V, q = _make_data(256, 16, 6)
        for kind in ("flat", "ivf", "lsh", "nsw"):
            ix = build_index(kind, V)
            idx, scores = ix.query(q, 4)
            assert idx.shape == (4,)
        with pytest.raises(ValueError):
            build_index("bogus", V)
