"""Recall / exactness tests for the k-MIPS substrate."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.mips import (
    FlatIndex, FlatAbsIndex, IVFIndex, LSHIndex, NSWIndex,
    augment_complement, build_index,
)
from repro.mips.transform import mips_to_knn_keys, mips_to_knn_query


def _make_data(n=512, dim=32, seed=0):
    rng = np.random.default_rng(seed)
    V = rng.standard_normal((n, dim)).astype(np.float32)
    q = rng.standard_normal((dim,)).astype(np.float32)
    return V, q


def _recall(idx, V, q, k):
    truth = np.argsort(-(V @ q))[:k]
    return len(set(np.asarray(idx).tolist()) & set(truth.tolist())) / k


class TestTransform:
    @given(st.integers(2, 50), st.integers(2, 16), st.integers(0, 100))
    @settings(max_examples=30, deadline=None)
    def test_preserves_inner_products_and_norms(self, n, dim, seed):
        rng = np.random.default_rng(seed)
        V = rng.standard_normal((n, dim)).astype(np.float32)
        q = rng.standard_normal((dim,)).astype(np.float32)
        Vt, M = mips_to_knn_keys(V)
        qt = mips_to_knn_query(q)
        np.testing.assert_allclose(Vt @ qt, V @ q, rtol=1e-5, atol=1e-5)
        norms = np.linalg.norm(Vt, axis=1)
        np.testing.assert_allclose(norms, M, rtol=1e-4)


class TestFlat:
    def test_exact(self):
        V, q = _make_data()
        idx, scores = FlatIndex(V, use_pallas="never").query(q, 10)
        assert _recall(idx, V, q, 10) == 1.0
        np.testing.assert_allclose(np.asarray(scores), np.sort(V @ q)[::-1][:10],
                                   rtol=1e-5)

    def test_flat_abs_matches_augmented(self):
        rng = np.random.default_rng(1)
        Q = rng.uniform(0, 1, size=(100, 16)).astype(np.float32)
        v = rng.standard_normal(16).astype(np.float32)
        v = v - v.mean()  # Σv = 0 — the histogram-difference regime
        aug = augment_complement(Q)
        idx_a, s_a = FlatIndex(aug, use_pallas="never").query(v, 7)
        idx_b, s_b = FlatAbsIndex(Q).query(v, 7)
        np.testing.assert_allclose(np.asarray(s_a), np.asarray(s_b), rtol=2e-4, atol=2e-5)
        assert set(np.asarray(idx_a).tolist()) == set(np.asarray(idx_b).tolist())


class TestIVF:
    def test_high_recall_on_clustered_data(self):
        rng = np.random.default_rng(0)
        centers = rng.standard_normal((16, 24)) * 4
        V = (centers[rng.integers(0, 16, 2048)] +
             rng.standard_normal((2048, 24)) * 0.3).astype(np.float32)
        q = V[3] + rng.standard_normal(24).astype(np.float32) * 0.05
        ix = IVFIndex(V, seed=0)
        idx, _ = ix.query(q, 10)
        assert _recall(idx, V, q, 10) >= 0.5
        assert ix.query_cost(10) < V.shape[0]

    def test_valid_ids_and_sorted_scores(self):
        V, q = _make_data(700, 24, 2)
        ix = IVFIndex(V, seed=1)
        idx, scores = ix.query(q, 16)
        s = np.asarray(scores)
        assert np.all(np.diff(s) <= 1e-6)
        assert np.all(np.asarray(idx) >= 0) and np.all(np.asarray(idx) < 700)


class TestIVFPallasRoute:
    def test_kernel_route_matches_xla_route(self):
        """`use_pallas="always"` (interpret off-TPU) must retrieve the same
        candidates as the XLA gather probe — same built structure."""
        V, q = _make_data(600, 24, 8)
        ix_x = IVFIndex(V, seed=3, train_iters=3, use_pallas="never")
        ix_p = IVFIndex(V, seed=3, train_iters=3, use_pallas="always")
        idx_x, s_x = ix_x.query(q, 12)
        idx_p, s_p = ix_p.query(q, 12)
        assert set(np.asarray(idx_x).tolist()) == set(np.asarray(idx_p).tolist())
        np.testing.assert_allclose(np.asarray(s_x), np.asarray(s_p),
                                   rtol=1e-5, atol=1e-5)

    def test_auto_falls_back_off_tpu(self):
        ix = IVFIndex(_make_data(100, 8, 1)[0], use_pallas="auto")
        import jax as _jax
        assert ix._resolve_pallas() == (_jax.default_backend() == "tpu")
        with pytest.raises(ValueError, match="auto|always|never"):
            IVFIndex(_make_data(64, 8, 1)[0], use_pallas="sometimes").query(
                np.zeros(8, np.float32), 2)

    def test_batch_probe_matches_single(self):
        V, _ = _make_data(512, 16, 9)
        ix = IVFIndex(V, seed=0, train_iters=3, use_pallas="never")
        rng = np.random.default_rng(1)
        Vb = rng.standard_normal((4, 16)).astype(np.float32)
        ib, sb = ix.query_in_graph_batch(jnp.asarray(Vb), 8)
        for b in range(4):
            i1, s1 = ix.query(Vb[b], 8)
            np.testing.assert_array_equal(np.asarray(ib[b]), np.asarray(i1))
            np.testing.assert_allclose(np.asarray(sb[b]), np.asarray(s1),
                                       rtol=1e-6, atol=1e-6)


class TestNoPerInstanceRecompilation:
    """Same-shaped index instances must share one compiled search program —
    the seed defined (and jitted) the query per instance, so every tenant
    or index rebuild retraced identical programs."""

    def _cache_size(self, fn):
        return fn._cache_size()

    def test_ivf_shares_compiled_query(self):
        from repro.mips.ivf import _query_xla

        V, q = _make_data(300, 16, 10)
        ix1 = IVFIndex(V, seed=0, train_iters=2, use_pallas="never")
        ix1.query(q, 5)
        size_after_first = self._cache_size(_query_xla)
        ix2 = IVFIndex(V, seed=1, train_iters=2, use_pallas="never")
        ix2.query(q, 5)
        assert self._cache_size(_query_xla) == size_after_first

    def test_flat_and_lsh_share_compiled_query(self):
        from repro.mips.flat import _flat_abs_query, _flat_query
        from repro.mips.lsh import _lsh_query

        V, q = _make_data(256, 16, 11)
        for cls, fn, kw in ((FlatIndex, _flat_query, dict(use_pallas="never")),
                            (FlatAbsIndex, _flat_abs_query,
                             dict(use_pallas="never")),
                            (LSHIndex, _lsh_query, dict(seed=0))):
            cls(V, **kw).query(q, 5)
            size = self._cache_size(fn)
            kw2 = dict(kw, seed=1) if "seed" in kw else kw
            cls(V, **kw2).query(q, 5)
            assert self._cache_size(fn) == size, cls.__name__


class TestLSH:
    def test_reasonable_recall(self):
        V, q = _make_data(1024, 32, 3)
        # make the true top item easy: plant a near-duplicate of the query
        V[0] = q * 3.0
        ix = LSHIndex(V, n_tables=16, seed=0)
        idx, _ = ix.query(q, 8)
        assert 0 in np.asarray(idx).tolist()


class TestNSW:
    def test_recall_against_exact(self):
        V, q = _make_data(2048, 32, 4)
        ix = NSWIndex(V, deg=16, ef=48, rounds=5, seed=0)
        idx, _ = ix.query(q, 10)
        assert _recall(idx, V, q, 10) >= 0.6

    def test_tiny_dataset(self):
        V, q = _make_data(10, 8, 5)
        ix = NSWIndex(V, deg=4, ef=8, rounds=2, seed=0)
        idx, scores = ix.query(q, 3)
        assert _recall(idx, V, q, 3) == 1.0


class TestFactory:
    def test_build_index(self):
        V, q = _make_data(256, 16, 6)
        for kind in ("flat", "ivf", "lsh", "nsw"):
            ix = build_index(kind, V)
            idx, scores = ix.query(q, 4)
            assert idx.shape == (4,)
        with pytest.raises(ValueError):
            build_index("bogus", V)
