"""Per-architecture smoke tests: reduced same-family configs run a forward /
train step on CPU; shapes + finiteness asserted. Decode paths are checked
against the full forward (teacher-forcing consistency) where applicable."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models import build_model


def _batch_for(cfg, B=2, S=24, key=None):
    if key is None:
        key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.is_encdec:
        return {
            "enc_embeds": jax.random.normal(k1, (B, cfg.enc_len, cfg.d_model),
                                            jnp.float32),
            "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
        }
    if cfg.input_embeds:
        return {
            "embeds": jax.random.normal(k1, (B, S, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_and_loss(arch):
    cfg = get_smoke_config(arch).with_(dtype="float32")
    model = build_model(cfg)
    params, specs = model.init(jax.random.PRNGKey(0))
    specs_struct = jax.tree.structure(specs,
                                      is_leaf=lambda x: isinstance(x, tuple))
    assert jax.tree.structure(params) == specs_struct
    batch = _batch_for(cfg)
    logits = model.forward(params, batch)
    B = batch.get("tokens", batch.get("labels")).shape[0]
    S = batch["tokens"].shape[1] if "tokens" in batch else batch["labels"].shape[1]
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss = model.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_grad_step(arch):
    cfg = get_smoke_config(arch).with_(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(1))
    batch = _batch_for(cfg, B=1, S=16)
    loss, grads = jax.value_and_grad(lambda p: model.loss(p, batch, remat=True))(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in leaves]
    assert sum(norms) > 0.0  # gradient actually flows


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_consistency(arch):
    """prefill(t < S) + decode(token S−1) ≡ forward(t ≤ S) at the last slot."""
    # high MoE capacity: token dropping is batch-size-dependent by design
    # (Switch semantics), which would confound the cache-correctness check.
    cfg = get_smoke_config(arch).with_(dtype="float32", moe_capacity_factor=8.0)
    if cfg.input_embeds:
        pytest.skip("embedding-input archs decode from token ids after fusion")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(2))
    B, S = 2, 20
    batch = _batch_for(cfg, B=B, S=S, key=jax.random.PRNGKey(3))
    tokens = batch["tokens"]
    full_logits = model.forward(params, batch)

    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, : S - 1]
    max_len = S + 4
    logits_pre, cache = model.prefill(params, pre_batch, max_len=max_len)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(full_logits[:, S - 2]),
                               rtol=2e-3, atol=2e-3)

    logits_dec, _ = model.decode_step(params, cache, tokens[:, S - 1:S],
                                      jnp.int32(S - 1))
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)


def test_decode_many_steps_matches_forward():
    """Multi-step decode for a hybrid arch (ring buffers + recurrent state)."""
    cfg = get_smoke_config("recurrentgemma-2b").with_(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(4))
    B, S0, steps = 1, 8, 6
    tokens = jax.random.randint(jax.random.PRNGKey(5), (B, S0 + steps), 0,
                                cfg.vocab_size)
    full = model.forward(params, {"tokens": tokens})
    _, cache = model.prefill(params, {"tokens": tokens[:, :S0]},
                             max_len=S0 + steps)
    for t in range(S0, S0 + steps):
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1],
                                          jnp.int32(t))
        np.testing.assert_allclose(np.asarray(logits), np.asarray(full[:, t]),
                                   rtol=5e-3, atol=5e-3)


def test_moe_routes_to_multiple_experts():
    cfg = get_smoke_config("qwen3-moe-30b-a3b").with_(dtype="float32")
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(6))
    batch = _batch_for(cfg, B=2, S=32)
    logits = model.forward(params, batch)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_exact_configs_match_assignment():
    from repro.configs import get_config

    c = get_config("nemotron-4-340b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (96, 18432, 96, 8, 73728, 256000)
    c = get_config("llama3-8b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab_size) == (32, 4096, 14336, 128256)
    c = get_config("qwen3-moe-30b-a3b")
    assert (c.n_experts, c.moe_top_k, c.d_ff) == (128, 8, 768)
    c = get_config("mamba2-130m")
    assert (c.n_layers, c.d_model, c.ssm_state, c.vocab_size) == (24, 768, 128, 50280)
    c = get_config("recurrentgemma-2b")
    assert sum(len(p) * n for p, n in c.stages) == 26
    c = get_config("llama4-scout-17b-a16e")
    assert sum(len(p) * n for p, n in c.stages) == 48
    assert c.subquadratic  # iRoPE chunked layout → long_500k eligible
