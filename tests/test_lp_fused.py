"""Cross-driver conformance tier for the private LP solvers (DESIGN.md §6).

The same contract shape `test_fused_driver.py` asserts for `run_mwem`:
host-vs-fused bitwise parity across {mode} × {index kind} × {margin_slack},
forced-overflow fallback, batch-vs-single lane parity, driver routing, and
the ledger/cost-bundle contract (`lp_release_cost` preview == executed
composed totals, both composition modes) — for BOTH LP solvers.

Unlike MWEM (whose per-iteration Θ(mU) matmuls can reassociate under XLA
fusion), the LP iteration bodies are small enough that host and fused runs
agree *bitwise* on their selection traces on one backend; these tests
assert exact equality of `selected`/`n_scored`/`overflow_count`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DualLPConfig, ScalarLPConfig, lp_release_cost, solve_constraint_private_lp,
    solve_constraint_private_lp_fused, solve_lp_batch, solve_scalar_lp,
    solve_scalar_lp_fused,
)
from repro.core.accountant import PrivacyLedger
from repro.core.lazy_em import fallback_key
from repro.core.lp_scalar import (_exact_select_lp, _lp_update,
                                  _resolve_lp_driver, _scalar_calibrate)
from repro.core.queries import random_feasible_lp, random_packing_lp
from repro.mips import (FlatIndex, IVFIndex, NSWIndex, lp_dual_rows,
                        lp_scalar_rows)

M, D = 256, 16
M2, D2 = 96, 48


@pytest.fixture(scope="module")
def scalar_lp():
    A, b, _ = random_feasible_lp(jax.random.PRNGKey(0), m=M, d=D)
    return A, b, lp_scalar_rows(A, b)


@pytest.fixture(scope="module")
def dual_lp():
    A, b, c = random_packing_lp(jax.random.PRNGKey(4), m=M2, d=D2)
    opt = float(c @ jnp.full((D2,), 1.0 / D2)) * 0.5
    return A, b, c, opt, lp_dual_rows(A, c, opt)


def _index(kind, rows):
    if kind is None:
        return None
    if kind == "flat":
        return FlatIndex(rows, use_pallas="never")
    return IVFIndex(rows, seed=0, train_iters=3, use_pallas="never")


CASES = [("exact", None, 0.0), ("fast", "flat", 0.0), ("fast", "flat", 0.05),
         ("fast", "ivf", 0.0), ("fast", "ivf", 0.05)]


class TestScalarConformance:
    @pytest.mark.parametrize("mode,kind,slack", CASES)
    def test_host_fused_bitwise_parity(self, scalar_lp, mode, kind, slack):
        A, b, rows = scalar_lp
        index = _index(kind, rows)
        mk = lambda drv: ScalarLPConfig(T=20, mode=mode, driver=drv,  # noqa: E731
                                        margin_slack=slack)
        rh = solve_scalar_lp(A, b, mk("host"), jax.random.PRNGKey(1),
                             index=index)
        rf = solve_scalar_lp(A, b, mk("fused"), jax.random.PRNGKey(1),
                             index=index)
        assert rf.selected == rh.selected
        assert rf.n_scored == rh.n_scored
        assert rf.overflow_count == rh.overflow_count
        np.testing.assert_allclose(np.asarray(rf.x_bar), np.asarray(rh.x_bar),
                                   atol=1e-5)
        assert rf.violated_frac == pytest.approx(rh.violated_frac, abs=1e-6)

    def test_fast_is_sublinear(self, scalar_lp):
        A, b, rows = scalar_lp
        res = solve_scalar_lp(A, b, ScalarLPConfig(T=20, mode="fast"),
                              jax.random.PRNGKey(2),
                              index=_index("flat", rows))
        assert res.overflow_count == 0
        assert np.mean(res.n_scored) < M * 0.9


class TestDualConformance:
    @pytest.mark.parametrize("mode,kind,slack", CASES)
    def test_host_fused_bitwise_parity(self, dual_lp, mode, kind, slack):
        A, b, c, opt, rows = dual_lp
        index = _index(kind, rows)
        mk = lambda drv: DualLPConfig(T=20, s=10, mode=mode, driver=drv,  # noqa: E731
                                      margin_slack=slack)
        rh = solve_constraint_private_lp(A, b, c, opt, mk("host"),
                                         jax.random.PRNGKey(5), index=index)
        rf = solve_constraint_private_lp(A, b, c, opt, mk("fused"),
                                         jax.random.PRNGKey(5), index=index)
        assert rf.selected == rh.selected
        assert rf.n_scored == rh.n_scored
        assert rf.overflow_count == rh.overflow_count
        np.testing.assert_allclose(np.asarray(rf.x_bar), np.asarray(rh.x_bar),
                                   atol=1e-5)
        assert rf.n_violated == rh.n_violated

    def test_fused_solution_in_k_opt(self, dual_lp):
        """Every fused iterate is a K_OPT vertex mixture: c^T x̄ = OPT."""
        A, b, c, opt, rows = dual_lp
        res = solve_constraint_private_lp_fused(
            A, b, c, opt, DualLPConfig(T=30, s=10, mode="fast"),
            jax.random.PRNGKey(6), index=_index("flat", rows))
        assert float(res.x_bar @ c) == pytest.approx(opt, rel=1e-3)


class TestOverflowFallback:
    def test_scalar_tiny_tail_cap_parity(self, scalar_lp):
        """tail_cap=1 forces C > cap almost every step; the fused in-graph
        `lax.cond` fallback must reproduce the host loop's redo bitwise."""
        A, b, rows = scalar_lp
        index = _index("flat", rows)
        mk = lambda drv: ScalarLPConfig(T=12, mode="fast", driver=drv,  # noqa: E731
                                        tail_cap=1)
        rh = solve_scalar_lp(A, b, mk("host"), jax.random.PRNGKey(3),
                             index=index)
        rf = solve_scalar_lp(A, b, mk("fused"), jax.random.PRNGKey(3),
                             index=index)
        assert rf.overflow_count > 0
        assert rf.overflow_count == rh.overflow_count
        assert rf.selected == rh.selected
        assert rf.n_scored == rh.n_scored
        # fallback iterations score all m candidates
        assert sum(s == M for s in rf.n_scored) == rf.overflow_count

    def test_dual_tiny_tail_cap_parity(self, dual_lp):
        A, b, c, opt, rows = dual_lp
        index = _index("flat", rows)
        mk = lambda drv: DualLPConfig(T=12, s=10, mode="fast", driver=drv,  # noqa: E731
                                      tail_cap=1)
        rh = solve_constraint_private_lp(A, b, c, opt, mk("host"),
                                         jax.random.PRNGKey(7), index=index)
        rf = solve_constraint_private_lp(A, b, c, opt, mk("fused"),
                                         jax.random.PRNGKey(7), index=index)
        assert rf.overflow_count > 0
        assert rf.overflow_count == rh.overflow_count
        assert rf.selected == rh.selected
        assert rf.n_scored == rh.n_scored

    def test_fallback_uses_fresh_key_regression(self, scalar_lp):
        """Regression: the exhaustive redo must draw from
        `fallback_key(k_sel)`, not from ``k_sel`` itself (which the failed
        lazy draw already consumed splits of). Replays the host key chain
        and checks every overflow iteration's selection against both."""
        A, b, rows = scalar_lp
        index = _index("flat", rows)
        cfg = ScalarLPConfig(T=12, mode="fast", driver="host", tail_cap=1)
        key = jax.random.PRNGKey(3)
        res = solve_scalar_lp(A, b, cfg, key, index=index)
        assert res.overflow_count > 0
        cal = _scalar_calibrate(jnp.asarray(A, jnp.float32), cfg)
        logX = jnp.zeros((D,), jnp.float32)
        x = jnp.full((D,), 1.0 / D, jnp.float32)
        kk = key
        reused_key_matches = 0
        for t in range(cal.T):
            kk, k_sel = jax.random.split(kk)
            if res.n_scored[t] == M:  # this iteration fell back
                fresh = int(_exact_select_lp(fallback_key(k_sel), A, b, x,
                                             cal.scale))
                old = int(_exact_select_lp(k_sel, A, b, x, cal.scale))
                assert res.selected[t] == fresh
                reused_key_matches += int(res.selected[t] == old)
            logX, x = _lp_update(logX, A[res.selected[t]], cal.eta, cal.rho)
        # the pre-fix behavior (redo with k_sel) would match on EVERY
        # overflow iteration; coincidental agreement on a few is fine
        assert reused_key_matches < res.overflow_count


class TestBatch:
    def test_batch_lane_matches_single_run(self, scalar_lp):
        A, b, rows = scalar_lp
        index = _index("flat", rows)
        cfg = ScalarLPConfig(T=12, mode="fast")
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(3)])
        batch = solve_lp_batch(A, b, cfg, keys, index=index)
        assert batch.x_bar.shape == (3, D)
        for lane in range(3):
            single = solve_scalar_lp_fused(A, b, cfg, jax.random.PRNGKey(lane),
                                           index=index)
            assert list(batch.selected[lane]) == single.selected
            assert list(batch.n_scored[lane]) == single.n_scored
            assert batch.overflow_counts[lane] == single.overflow_count
            np.testing.assert_allclose(np.asarray(batch.x_bar[lane]),
                                       np.asarray(single.x_bar), atol=1e-6)
            assert batch.violated_fracs[lane] == pytest.approx(
                single.violated_frac, abs=1e-6)

    def test_batched_b_instances_exact_mode(self, scalar_lp):
        """Per-lane b instances (exact mode): each lane reproduces a
        standalone fused run on its own instance."""
        A, b, _ = scalar_lp
        b2 = jnp.asarray(np.asarray(b) + 0.3)
        bb = jnp.stack([jnp.asarray(b), b2])
        cfg = ScalarLPConfig(T=10, mode="exact")
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2)])
        batch = solve_lp_batch(A, bb, cfg, keys)
        for lane, b_lane in enumerate((b, b2)):
            single = solve_scalar_lp_fused(A, b_lane, cfg,
                                           jax.random.PRNGKey(lane))
            assert list(batch.selected[lane]) == single.selected
            np.testing.assert_allclose(np.asarray(batch.x_bar[lane]),
                                       np.asarray(single.x_bar), atol=1e-6)
        # different instances genuinely produce different runs
        assert list(batch.selected[0]) != list(batch.selected[1])

    def test_batched_b_fast_mode_raises(self, scalar_lp):
        A, b, rows = scalar_lp
        bb = jnp.stack([jnp.asarray(b)] * 2)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(2)])
        with pytest.raises(ValueError, match="per-lane b"):
            solve_lp_batch(A, bb, ScalarLPConfig(T=4, mode="fast"), keys,
                           index=_index("flat", rows))

    def test_host_driver_rejected(self, scalar_lp):
        A, b, rows = scalar_lp
        keys = jnp.stack([jax.random.PRNGKey(0)])
        with pytest.raises(ValueError, match="fused driver"):
            solve_lp_batch(A, b, ScalarLPConfig(T=4, driver="host"), keys,
                           index=_index("flat", rows))

    def test_per_lane_ledgers(self, scalar_lp):
        A, b, rows = scalar_lp
        index = _index("flat", rows)
        cfg = ScalarLPConfig(T=8, mode="fast")
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(3)])
        lanes = [PrivacyLedger(), None, PrivacyLedger()]
        batch = solve_lp_batch(A, b, cfg, keys, index=index, ledgers=lanes)
        for lane in (lanes[0], lanes[2]):
            assert lane.composed() == batch.ledger.composed()
        with pytest.raises(ValueError, match="one entry per lane"):
            solve_lp_batch(A, b, cfg, keys[:2], index=index,
                           ledgers=[PrivacyLedger()])


class TestRouting:
    def test_auto_routes_like_mwem(self, scalar_lp):
        A, b, rows = scalar_lp
        flat = _index("flat", rows)
        nsw = NSWIndex(rows, deg=8, ef=16, rounds=2, seed=0)
        assert _resolve_lp_driver(ScalarLPConfig(), flat) == "fused"
        # NSW's beam search traces since the megakernel PR: it fuses like
        # every other built-in index
        assert _resolve_lp_driver(ScalarLPConfig(), nsw) == "fused"
        assert _resolve_lp_driver(ScalarLPConfig(mode="exact"), None) == "fused"

        class HostOnly:
            supports_in_graph = False
            approx_margin = 0.0
            failure_mass = 0.0

        assert _resolve_lp_driver(ScalarLPConfig(), HostOnly()) == "host"
        with pytest.raises(ValueError, match="host"):
            solve_scalar_lp(A, b, ScalarLPConfig(T=4, driver="fused"),
                            jax.random.PRNGKey(0), index=HostOnly())
        with pytest.raises(ValueError, match="unknown driver"):
            solve_scalar_lp(A, b, ScalarLPConfig(T=4, driver="warp"),
                            jax.random.PRNGKey(0), index=flat)
        with pytest.raises(ValueError, match="k-MIPS index"):
            solve_scalar_lp(A, b, ScalarLPConfig(T=4, mode="fast"),
                            jax.random.PRNGKey(0))

    def test_nsw_fuses_with_host_parity(self, scalar_lp):
        """The former host-only index now rides both drivers — and they
        must tell the same selection story (full matrix closure)."""
        A, b, rows = scalar_lp
        nsw = NSWIndex(rows, deg=8, ef=16, rounds=2, seed=0)
        res = solve_scalar_lp(A, b, ScalarLPConfig(T=8, mode="fast"),
                              jax.random.PRNGKey(1), index=nsw)
        host = solve_scalar_lp(A, b,
                               ScalarLPConfig(T=8, mode="fast",
                                              driver="host"),
                               jax.random.PRNGKey(1), index=nsw)
        assert len(res.selected) == 8
        assert np.isfinite(res.violated_frac)
        assert res.selected == host.selected


class TestLedgerContract:
    """The (ε, δ) totals each LP solver records equal `PrivacyLedger.preview`
    of its `lp_release_cost` bundle — in both composition modes, on both
    drivers, including the approx-slack and index-failure paths. The same
    guarantee `release_cost` gives the linear-query service."""

    @pytest.mark.parametrize("tight", [False, True])
    @pytest.mark.parametrize("driver", ["host", "fused"])
    def test_scalar_totals_equal_cost_preview(self, scalar_lp, driver, tight):
        A, b, rows = scalar_lp
        for mode, index in (("exact", None), ("fast", _index("flat", rows))):
            cfg = ScalarLPConfig(eps=0.7, delta=1e-3, T=16, mode=mode,
                                 driver=driver)
            res = solve_scalar_lp(A, b, cfg, jax.random.PRNGKey(1),
                                  index=index)
            exp = PrivacyLedger().preview(*lp_release_cost(cfg, A, index=index),
                                          tight=tight)
            assert res.ledger.composed(tight=tight) == exp

    @pytest.mark.parametrize("tight", [False, True])
    @pytest.mark.parametrize("driver", ["host", "fused"])
    def test_dual_totals_equal_cost_preview(self, dual_lp, driver, tight):
        A, b, c, opt, rows = dual_lp
        for mode, index in (("exact", None), ("fast", _index("flat", rows))):
            cfg = DualLPConfig(eps=0.7, delta=1e-3, T=16, s=10, mode=mode,
                               driver=driver)
            res = solve_constraint_private_lp(A, b, c, opt, cfg,
                                              jax.random.PRNGKey(5),
                                              index=index)
            exp = PrivacyLedger().preview(*lp_release_cost(cfg, A, index=index),
                                          tight=tight)
            assert res.ledger.composed(tight=tight) == exp

    def test_approx_slack_path(self, scalar_lp):
        """An index with a declared approximation margin c charges +2c per
        iteration (Thm F.2) unless margin_slack > 0 lowers the threshold."""
        A, b, rows = scalar_lp
        index = IVFIndex(rows, seed=0, train_iters=3, approx_margin=0.05,
                         use_pallas="never")
        cfg = ScalarLPConfig(T=10, mode="fast")
        res = solve_scalar_lp(A, b, cfg, jax.random.PRNGKey(1), index=index)
        assert res.ledger.approx_slack == pytest.approx(10 * 2 * 0.05)
        assert res.ledger.composed() == PrivacyLedger().preview(
            *lp_release_cost(cfg, A, index=index))
        cfg_slack = ScalarLPConfig(T=10, mode="fast", margin_slack=0.05)
        res2 = solve_scalar_lp(A, b, cfg_slack, jax.random.PRNGKey(1),
                               index=index)
        assert res2.ledger.approx_slack == 0.0
        assert res2.ledger.composed() == PrivacyLedger().preview(
            *lp_release_cost(cfg_slack, A, index=index))

    def test_index_failure_path(self, scalar_lp, dual_lp):
        A, b, rows = scalar_lp
        res = solve_scalar_lp(A, b, ScalarLPConfig(T=4, mode="fast"),
                              jax.random.PRNGKey(0), index=_index("flat", rows))
        # FlatIndex is exact: failure_mass = 0 recorded, δ untouched
        assert res.ledger.index_failure_mass == 0.0
        ivf = IVFIndex(rows, seed=0, train_iters=3, use_pallas="never")
        res = solve_scalar_lp(A, b, ScalarLPConfig(T=4, mode="fast"),
                              jax.random.PRNGKey(0), index=ivf)
        assert res.ledger.index_failure_mass == pytest.approx(1.0 / M)
        assert res.ledger.composed()[1] >= 1.0 / M

    def test_cost_bundle_unknown_config_raises(self, scalar_lp):
        A, _, _ = scalar_lp
        with pytest.raises(TypeError, match="unknown LP config"):
            lp_release_cost(object(), A)
