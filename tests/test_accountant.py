"""Privacy accounting (Thm B.1) and calibration."""

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.accountant import PrivacyLedger, advanced_composition, calibrate_eps0


class TestComposition:
    def test_matches_paper_formula(self):
        eps0, k, dp = 0.1, 100, 1e-6
        eps, delta = advanced_composition(eps0, 0.0, k, dp)
        expected = eps0 * math.sqrt(2 * k * math.log(1 / dp)) + 2 * k * eps0 ** 2
        assert math.isclose(eps, expected)
        assert delta == dp

    def test_tight_not_worse_for_small_eps(self):
        loose, _ = advanced_composition(0.01, 0, 1000, 1e-9, tight=False)
        tight, _ = advanced_composition(0.01, 0, 1000, 1e-9, tight=True)
        assert tight <= loose

    @given(st.floats(1e-4, 0.5), st.integers(1, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_monotone_in_k(self, eps0, k):
        e1, _ = advanced_composition(eps0, 0, k, 1e-9)
        e2, _ = advanced_composition(eps0, 0, k + 1, 1e-9)
        assert e2 >= e1

    def test_calibration_roundtrip(self):
        """The paper's ε₀ = ε/√(T ln 1/δ) keeps composed ε near target."""
        eps, delta, T = 1.0, 1e-3, 400
        eps0 = calibrate_eps0(eps, delta, T, "mwem")
        composed, _ = advanced_composition(eps0, 0, T, delta)
        assert composed < 2.5 * eps  # same order as the target


class TestLedger:
    def test_grouping_and_slack(self):
        led = PrivacyLedger(target_delta_prime=1e-9)
        for _ in range(50):
            led.record(0.05, 0.0, "em")
        led.record_index_failure(1e-4)
        led.record_approx_slack(0.01)
        eps, delta = led.composed()
        base, _ = advanced_composition(0.05, 0, 50, 1e-9)
        assert math.isclose(eps, base + 0.02, rel_tol=1e-9)
        assert delta >= 1e-4

    def test_basic_composition(self):
        led = PrivacyLedger()
        led.record(0.1)
        led.record(0.2)
        eps, delta = led.basic()
        assert math.isclose(eps, 0.3)
        assert delta == 0.0
