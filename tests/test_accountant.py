"""Privacy accounting (Thm B.1) and calibration."""

import math

import pytest
from _hypothesis_compat import given, settings, st

from repro.core.accountant import PrivacyLedger, advanced_composition, calibrate_eps0


class TestComposition:
    def test_matches_paper_formula(self):
        eps0, k, dp = 0.1, 100, 1e-6
        eps, delta = advanced_composition(eps0, 0.0, k, dp)
        expected = eps0 * math.sqrt(2 * k * math.log(1 / dp)) + 2 * k * eps0 ** 2
        assert math.isclose(eps, expected)
        assert delta == dp

    def test_tight_not_worse_for_small_eps(self):
        loose, _ = advanced_composition(0.01, 0, 1000, 1e-9, tight=False)
        tight, _ = advanced_composition(0.01, 0, 1000, 1e-9, tight=True)
        assert tight <= loose

    @given(st.floats(1e-4, 0.5), st.integers(1, 10_000))
    @settings(max_examples=50, deadline=None)
    def test_monotone_in_k(self, eps0, k):
        e1, _ = advanced_composition(eps0, 0, k, 1e-9)
        e2, _ = advanced_composition(eps0, 0, k + 1, 1e-9)
        assert e2 >= e1

    def test_calibration_roundtrip(self):
        """The paper's ε₀ = ε/√(T ln 1/δ) keeps composed ε near target."""
        eps, delta, T = 1.0, 1e-3, 400
        eps0 = calibrate_eps0(eps, delta, T, "mwem")
        composed, _ = advanced_composition(eps0, 0, T, delta)
        assert composed < 2.5 * eps  # same order as the target


class TestLedger:
    def test_grouping_and_slack(self):
        led = PrivacyLedger(target_delta_prime=1e-9)
        for _ in range(50):
            led.record(0.05, 0.0, "em")
        led.record_index_failure(1e-4)
        led.record_approx_slack(0.01)
        eps, delta = led.composed()
        base, _ = advanced_composition(0.05, 0, 50, 1e-9)
        assert math.isclose(eps, base + 0.02, rel_tol=1e-9)
        assert delta >= 1e-4

    def test_basic_composition(self):
        led = PrivacyLedger()
        led.record(0.1)
        led.record(0.2)
        eps, delta = led.basic()
        assert math.isclose(eps, 0.3)
        assert delta == 0.0


class TestBudgetHelpers:
    """`remaining` / `would_exceed` / `preview` — the admission-control
    surface — checked against `advanced_composition` directly in both the
    default and tight composition modes."""

    @pytest.mark.parametrize("tight", [False, True])
    def test_remaining_matches_advanced_composition(self, tight):
        led = PrivacyLedger(target_delta_prime=1e-9)
        for _ in range(40):
            led.record(0.02, 1e-8, "em")
        spent, spent_d = advanced_composition(0.02, 1e-8, 40, 1e-9, tight)
        eps_rem, delta_rem = led.remaining(2.0, 1e-4, tight=tight)
        assert math.isclose(eps_rem, 2.0 - spent, rel_tol=1e-12)
        assert math.isclose(delta_rem, 1e-4 - spent_d, rel_tol=1e-9)

    @pytest.mark.parametrize("tight", [False, True])
    def test_preview_is_pure_and_matches_record(self, tight):
        led = PrivacyLedger(target_delta_prime=1e-9)
        led.record(0.05, 0.0, "em")
        events = [(0.05, 0.0, "em")] * 9 + [(0.01, 0.0, "laplace")] * 10
        before = list(led.events)
        previewed = led.preview(events, gamma=1e-5, slack=0.002, tight=tight)
        assert led.events == before  # no mutation
        led.record_events(events, gamma=1e-5, slack=0.002)
        assert led.composed(tight=tight) == previewed
        # cross-check against advanced_composition per homogeneous group
        e1, d1 = advanced_composition(0.05, 0.0, 10, 1e-9, tight)
        e2, d2 = advanced_composition(0.01, 0.0, 10, 1e-9, tight)
        assert math.isclose(previewed[0], e1 + e2 + 0.002, rel_tol=1e-12)
        assert math.isclose(previewed[1], d1 + d2 + 1e-5, rel_tol=1e-12)

    @pytest.mark.parametrize("tight", [False, True])
    def test_would_exceed_threshold(self, tight):
        led = PrivacyLedger(target_delta_prime=1e-9)
        events = [(0.1, 0.0, "em")] * 5
        eps_cost, delta_cost = led.preview(events, tight=tight)
        assert not led.would_exceed(eps_cost * 1.01, delta_cost * 1.01,
                                    events, tight=tight)
        assert led.would_exceed(eps_cost * 0.99, delta_cost * 1.01,
                                events, tight=tight)
        # δ overflow alone also rejects
        assert led.would_exceed(eps_cost * 1.01, delta_cost * 0.5,
                                events, gamma=delta_cost, tight=tight)

    def test_remaining_can_go_negative(self):
        led = PrivacyLedger()
        led.record(1.0)
        eps_rem, _ = led.remaining(0.5, 1e-3)
        assert eps_rem < 0.0


_EVENTS = st.lists(
    st.tuples(st.floats(1e-4, 0.5), st.floats(0.0, 1e-6),
              st.sampled_from(["em", "laplace", "lp_em"])),
    min_size=1, max_size=16)


class TestTwoPhaseCommit:
    """`reserve`/`commit`/`abort` — phase one/two of the serving tier's
    budget commit (DESIGN.md §10). The contract the chaos suite builds on:
    reserve→commit must be indistinguishable from a direct `record_events`
    (ledger dataclass equality ⇒ identical composed (ε, δ) in both modes),
    and reserve→abort must leave no trace."""

    @pytest.mark.parametrize("tight", [False, True])
    @given(events=_EVENTS, gamma=st.floats(0.0, 1e-4),
           slack=st.floats(0.0, 0.01))
    @settings(max_examples=50, deadline=None)
    def test_reserve_commit_equals_record_events(self, tight, events,
                                                 gamma, slack):
        events = [tuple(e) for e in events]
        direct = PrivacyLedger(target_delta_prime=1e-9)
        direct.record(0.05, 0.0, "em")  # shared pre-existing spend
        staged = PrivacyLedger(target_delta_prime=1e-9)
        staged.record(0.05, 0.0, "em")
        direct.record_events(events, gamma=gamma, slack=slack)
        rid = staged.reserve(events, gamma=gamma, slack=slack)
        staged.commit(rid)
        assert staged == direct  # events/γ/slack dataclass equality
        assert staged.composed(tight=tight) == direct.composed(tight=tight)
        assert not staged.reservations

    @given(events=_EVENTS, gamma=st.floats(0.0, 1e-4),
           slack=st.floats(0.0, 0.01))
    @settings(max_examples=50, deadline=None)
    def test_reserve_abort_is_noop(self, events, gamma, slack):
        events = [tuple(e) for e in events]
        led = PrivacyLedger(target_delta_prime=1e-9)
        led.record(0.02, 0.0, "em")
        baseline = PrivacyLedger(target_delta_prime=1e-9)
        baseline.record(0.02, 0.0, "em")
        rid = led.reserve(events, gamma=gamma, slack=slack)
        led.abort(rid)
        assert led == baseline
        assert not led.reservations

    def test_hooks_fire_on_commit_not_reserve(self):
        led = PrivacyLedger()
        calls = []
        led.add_hook(lambda lg: calls.append(len(lg.events)))
        rid = led.reserve([(0.1, 0.0, "em")])
        assert calls == []  # phase one holds budget without spending it
        led.commit(rid)
        assert calls == [1]  # phase two routes through record_events
        rid2 = led.reserve([(0.1, 0.0, "em")])
        led.abort(rid2)
        assert calls == [1]  # refunds are silent too

    def test_reserved_bundle_pools_open_reservations(self):
        led = PrivacyLedger()
        led.reserve([(0.1, 0.0, "em")], gamma=1e-6, slack=0.001)
        r2 = led.reserve([(0.2, 1e-8, "laplace")], gamma=2e-6, slack=0.002)
        events, gamma, slack = led.reserved_bundle()
        assert events == [(0.1, 0.0, "em"), (0.2, 1e-8, "laplace")]
        assert math.isclose(gamma, 3e-6) and math.isclose(slack, 0.003)
        led.abort(r2)
        events, gamma, slack = led.reserved_bundle()
        assert events == [(0.1, 0.0, "em")]

    def test_unknown_or_double_resolution_raises(self):
        led = PrivacyLedger()
        rid = led.reserve([(0.1, 0.0, "em")])
        led.commit(rid)
        with pytest.raises(KeyError):
            led.commit(rid)  # double charge is structurally impossible
        with pytest.raises(KeyError):
            led.abort(rid)
        with pytest.raises(KeyError):
            led.abort(12345)
