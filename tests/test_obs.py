"""Observability layer (DESIGN.md §8): metrics core, mechanism telemetry,
ledger-fed budget gauges, and the zero-effect contract.

The load-bearing invariant is the last one: with obs enabled vs disabled,
every driver's *results* (p_hat, selected, n_scored) must be bitwise
identical — the obs layer only ever reads traces the drivers already
return and attaches pure-metadata profiler scopes.
"""

import json
import math
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MWEMConfig, run_mwem, run_mwem_batch, run_mwem_fused
from repro.core.queries import gaussian_histogram, random_binary_queries
from repro.mips import FlatAbsIndex
from repro.obs import trace as obs_trace
from repro.obs.events import EventSink
from repro.obs.metrics import (GROWTH, Histogram, MetricsRegistry,
                               default_registry, series_key)
from repro.obs.telemetry import aggregate_traces, publish

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(scope="module")
def workload():
    key = jax.random.PRNGKey(0)
    kh, kq = jax.random.split(key)
    U, m, n = 64, 128, 300
    h = gaussian_histogram(kh, n, U)
    Q = random_binary_queries(kq, m, U)
    return Q, h, n


@pytest.fixture(autouse=True)
def _obs_enabled():
    """Every test starts from the default switch state."""
    obs_trace.set_enabled(True)
    yield
    obs_trace.set_enabled(True)


class TestHistogram:
    def test_counts_and_extremes_are_exact(self):
        hist = Histogram()
        vals = [0.001, 0.5, 0.5, 2.0, 100.0]
        for v in vals:
            hist.observe(v)
        snap = hist.snapshot()
        assert snap["count"] == len(vals)
        assert snap["sum"] == pytest.approx(sum(vals))
        assert snap["min"] == 0.001 and snap["max"] == 100.0
        assert snap["mean"] == pytest.approx(sum(vals) / len(vals))

    def test_quantile_within_one_bucket(self):
        """The log-bucket estimate must land within one GROWTH factor of
        the true quantile, at every probe point of a geometric series."""
        hist = Histogram()
        vals = [1.5 ** i for i in range(40)]
        for v in vals:
            hist.observe(v)
        for q in (0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0):
            # the estimator is nearest-rank with floor(q·(n−1))
            true = vals[int(q * (len(vals) - 1))]
            est = hist.quantile(q)
            assert true / GROWTH <= est <= true * GROWTH, (q, true, est)

    def test_zero_bucket_and_clamping(self):
        hist = Histogram()
        hist.observe(0.0)
        hist.observe(-1.0)  # durations can round to/below 0 on coarse clocks
        hist.observe(3.0)
        assert hist.quantile(0.0) == 0.0
        # the top bucket's geometric midpoint clamps to the observed max
        assert hist.quantile(1.0) <= 3.0
        assert hist.snapshot()["min"] == -1.0

    def test_single_value_all_quantiles_exact(self):
        hist = Histogram()
        hist.observe(0.042)
        for q in (0.0, 0.5, 0.99, 1.0):
            assert hist.quantile(q) == pytest.approx(0.042, rel=GROWTH - 1)

    def test_empty_and_invalid(self):
        hist = Histogram()
        assert math.isnan(hist.quantile(0.5))
        assert hist.snapshot() == {"count": 0, "sum": 0.0}
        with pytest.raises(ValueError):
            hist.observe(float("nan"))
        with pytest.raises(ValueError):
            hist.quantile(1.5)


class TestRegistry:
    def test_counter_gauge_snapshot_round_trip(self):
        reg = MetricsRegistry()
        reg.counter("reqs_total", kind="lp").inc()
        reg.counter("reqs_total", kind="lp").inc(2)
        reg.counter("reqs_total", kind="mwem").inc()
        reg.gauge("occupancy").set(0.75)
        reg.histogram("lat_seconds", kind="lp").observe(0.1)
        snap = reg.snapshot()
        assert snap["counters"]["reqs_total{kind=lp}"] == 3.0
        assert snap["counters"]["reqs_total{kind=mwem}"] == 1.0
        assert snap["gauges"]["occupancy"] == 0.75
        assert snap["histograms"]["lat_seconds{kind=lp}"]["count"] == 1
        # snapshot survives JSON round-trip (the BENCH artifact path)
        assert json.loads(reg.to_json()) == json.loads(json.dumps(snap))

    def test_series_identity_is_name_plus_sorted_labels(self):
        reg = MetricsRegistry()
        a = reg.counter("c", x="1", y="2")
        b = reg.counter("c", y="2", x="1")  # label order irrelevant
        assert a is b
        assert series_key("c", (("x", "1"), ("y", "2"))) == "c{x=1,y=2}"

    def test_kind_conflict_and_monotonic_counter(self):
        reg = MetricsRegistry()
        reg.counter("n").inc()
        with pytest.raises(TypeError):
            reg.gauge("n")
        with pytest.raises(ValueError):
            reg.counter("n").inc(-1)

    def test_prometheus_exposition(self):
        reg = MetricsRegistry()
        reg.counter("waves_total", kind="mwem").inc(4)
        reg.histogram("lat_seconds").observe(0.25)
        text = reg.to_prometheus()
        assert "# TYPE waves_total counter" in text
        assert '# TYPE lat_seconds summary' in text
        assert 'waves_total{kind="mwem"} 4' in text
        assert 'lat_seconds{quantile="0.95"}' in text
        assert "lat_seconds_count 1" in text

    def test_reset(self):
        reg = MetricsRegistry()
        reg.counter("x").inc()
        reg.reset()
        assert reg.snapshot() == {"counters": {}, "gauges": {},
                                  "histograms": {}}


class TestTelemetry:
    def test_aggregate_traces_math(self):
        m = 100
        tel = aggregate_traces(workload="mwem", driver="fused", mode="fast",
                               m=m, n_scored=[10, 20, 100, 30],
                               overflow_count=1, total_seconds=2.0,
                               amortized=True)
        assert tel.T == 4 and tel.lanes == 1
        assert tel.n_scored_total == 160 and tel.n_scored_max == 100
        assert tel.n_scored_mean == pytest.approx(40.0)
        assert tel.overflow_rate == pytest.approx(0.25)
        assert tel.lazy_fraction == pytest.approx(0.75)  # 3 of 4 iters < m
        assert tel.sqrt_m_ratio == pytest.approx(40.0 / math.sqrt(m))
        d = tel.as_dict()
        assert d["driver"] == "fused" and d["total_seconds"] == 2.0

    def test_lanes_divide_iterations(self):
        tel = aggregate_traces(workload="mwem", driver="waved", mode="fast",
                               m=64, n_scored=np.full((3, 5), 8),
                               overflow_count=0, total_seconds=1.0,
                               amortized=True, lanes=3)
        assert tel.T == 5 and tel.lanes == 3
        assert tel.n_scored_total == 120

    def test_publish_gated_on_switch(self):
        tel = aggregate_traces(workload="mwem", driver="host", mode="exact",
                               m=64, n_scored=[64, 64], overflow_count=0,
                               total_seconds=0.1, amortized=False)
        reg = MetricsRegistry()
        with obs_trace.disabled():
            publish(tel, registry=reg)
        assert reg.snapshot()["counters"] == {}  # nothing published
        publish(tel, registry=reg)
        snap = reg.snapshot()
        key = "mechanism_runs_total{driver=host,mode=exact,workload=mwem}"
        assert snap["counters"][key] == 1.0
        assert snap["gauges"][
            "mechanism_lazy_fraction{driver=host,mode=exact,workload=mwem}"
        ] == 0.0


class TestDriverTelemetry:
    """Every driver's result carries a telemetry record regardless of the
    switch — the record is part of the result; only *publication* and
    profiler annotation are gated."""

    def test_fused_record(self, workload):
        Q, h, n = workload
        cfg = MWEMConfig(T=6, mode="fast", n_records=n)
        res = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(0),
                             index=FlatAbsIndex(Q))
        tel = res.telemetry
        assert tel is not None and tel.driver == "fused"
        assert tel.workload == "mwem" and tel.mode == "fast"
        assert tel.m == Q.shape[0] and tel.T == 6
        assert tel.n_scored_total == sum(res.n_scored)
        assert tel.overflow_count == res.overflow_count
        assert tel.total_seconds == pytest.approx(sum(res.iter_seconds))

    def test_record_present_even_when_disabled(self, workload):
        Q, h, n = workload
        cfg = MWEMConfig(T=4, mode="exact", n_records=n)
        with obs_trace.disabled():
            res = run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(0))
        assert res.telemetry is not None
        assert res.telemetry.lazy_fraction == 0.0  # exact scores all m rows

    def test_host_record_not_amortized(self, workload):
        Q, h, n = workload
        cfg = MWEMConfig(T=4, mode="exact", n_records=n, driver="host")
        res = run_mwem(Q, h, cfg, jax.random.PRNGKey(0))
        assert res.telemetry.driver == "host"
        assert not res.telemetry.amortized
        assert res.telemetry.lanes == 1

    def test_batch_record_spans_lanes(self, workload):
        Q, h, n = workload
        B, T = 3, 5
        cfg = MWEMConfig(T=T, mode="fast", n_records=n)
        keys = jnp.stack([jax.random.PRNGKey(s) for s in range(B)])
        batch = run_mwem_batch(Q, h, cfg, keys, index=FlatAbsIndex(Q))
        assert batch.telemetry.lanes == B and batch.telemetry.T == T
        assert batch.telemetry.n_scored_total == int(
            np.asarray(batch.n_scored).sum())


class TestBitwiseParity:
    """ISSUE acceptance: obs enabled vs disabled changes nothing about the
    mechanism outputs — bitwise, per driver, per mode."""

    @staticmethod
    def _pair(run):
        obs_trace.set_enabled(True)
        on = run()
        with obs_trace.disabled():
            off = run()
        assert np.asarray(on.p_hat).tobytes() == np.asarray(off.p_hat).tobytes()
        assert on.selected == off.selected
        assert on.n_scored == off.n_scored
        assert on.overflow_count == off.overflow_count

    @pytest.mark.parametrize("mode", ["exact", "fast"])
    def test_fused(self, workload, mode):
        Q, h, n = workload
        cfg = MWEMConfig(T=5, mode=mode, n_records=n)
        index = FlatAbsIndex(Q) if mode == "fast" else None
        self._pair(lambda: run_mwem_fused(Q, h, cfg, jax.random.PRNGKey(3),
                                          index=index))

    @pytest.mark.parametrize("mode", ["exact", "fast"])
    def test_host(self, workload, mode):
        Q, h, n = workload
        cfg = MWEMConfig(T=5, mode=mode, n_records=n, driver="host")
        index = FlatAbsIndex(Q) if mode == "fast" else None
        self._pair(lambda: run_mwem(Q, h, cfg, jax.random.PRNGKey(3),
                                    index=index))

    @pytest.mark.parametrize("mode", ["exact", "fast"])
    def test_sharded(self, workload, mode):
        from repro.core.distributed import run_mwem_sharded

        Q, h, n = workload
        cfg = MWEMConfig(T=4, mode=mode, n_records=n)
        # one-device mesh: same code path (shard_map scan), no subprocess
        index = None  # fast mode builds ShardedIVFIndex(Q, n_shards=1)
        self._pair(lambda: run_mwem_sharded(Q, h, cfg, jax.random.PRNGKey(3),
                                            index=index))


class TestLedgerGauges:
    """The ledger hook keeps the per-tenant budget gauges equal to
    `PrivacyLedger.composed()` in the service's composition mode."""

    @pytest.mark.parametrize("tight", [False, True])
    def test_gauges_track_composed(self, workload, tight):
        from repro.serve import ReleaseService

        Q, h, n = workload
        reg = MetricsRegistry()
        svc = ReleaseService(Q, MWEMConfig(eps=0.5, delta=1e-3, T=4,
                                           mode="exact"),
                             wave_size=2, auto_flush=False,
                             tight_composition=tight, registry=reg)
        svc.create_session("t0", eps_budget=50.0, delta_budget=0.5,
                           h=np.asarray(h), n_records=n)
        snap = reg.snapshot()["gauges"]
        assert snap["tenant_eps_spent{tenant=t0}"] == 0.0  # registered at 0
        svc.submit("t0")
        svc.flush()
        sess = svc.session("t0")
        eps, delta = sess.ledger.composed(tight=tight)
        assert eps > 0
        snap = reg.snapshot()["gauges"]
        assert snap["tenant_eps_spent{tenant=t0}"] == pytest.approx(eps)
        assert snap["tenant_delta_spent{tenant=t0}"] == pytest.approx(delta)
        assert snap["tenant_eps_remaining{tenant=t0}"] == pytest.approx(
            50.0 - eps)
        assert snap["tenant_delta_remaining{tenant=t0}"] == pytest.approx(
            0.5 - delta)

    def test_hooks_do_not_change_ledger_equality(self):
        from repro.core.accountant import PrivacyLedger

        a, b = PrivacyLedger(), PrivacyLedger()
        a.add_hook(lambda ledger: None)
        a.record(0.1, label="x")
        b.record(0.1, label="x")
        assert a == b  # hooks excluded from dataclass comparison


class TestServiceMetrics:
    @pytest.fixture(scope="class")
    def served(self, workload):
        from repro.serve import ReleaseService

        Q, h, n = workload
        reg = MetricsRegistry()
        svc = ReleaseService(Q, MWEMConfig(eps=0.5, delta=1e-3, T=4,
                                           mode="exact"),
                             wave_size=4, auto_flush=False, registry=reg)
        for t in ("a", "b"):
            svc.create_session(t, eps_budget=50.0, delta_budget=0.5,
                               h=np.asarray(h), n_records=n)
            svc.submit(t)
        svc.flush()
        q = np.asarray(Q)[0]
        svc.answer("a", q)
        svc.answer("a", q)  # repeat → cache hit
        svc.create_session("broke", eps_budget=1e-9, delta_budget=0.5,
                           h=np.asarray(h), n_records=n)
        svc.submit("broke")
        return svc

    def test_latency_histogram_quantiles(self, served):
        snap = served.metrics_snapshot()
        lat = snap["histograms"]["admission_to_answer_seconds{kind=mwem}"]
        assert lat["count"] == 2
        for p in ("p50", "p95", "p99"):
            assert lat[p] > 0
        ans = snap["histograms"]["admission_to_answer_seconds{kind=answer}"]
        assert ans["count"] == 2

    def test_wave_gauges_and_counters(self, served):
        snap = served.metrics_snapshot()
        assert snap["counters"]["wave_dispatches_total{kind=mwem}"] == 1.0
        # wave of 2 real tickets padded to wave_size 4
        assert snap["counters"]["wave_padded_slots_total{kind=mwem}"] == 2.0
        assert snap["gauges"]["wave_occupancy{kind=mwem}"] == 0.5
        assert snap["gauges"]["wave_padding_waste{kind=mwem}"] == 0.5

    def test_cache_and_rejection_counters(self, served):
        snap = served.metrics_snapshot()
        assert snap["counters"]["answer_cache_hits_total"] == 1.0
        assert snap["counters"]["answer_cache_misses_total"] == 1.0
        key = "admission_rejections_total{kind=mwem,tenant=broke}"
        assert snap["counters"][key] == 1.0

    def test_ticket_latency_stamped(self, served):
        # resolved tickets carry their admission→answer latency
        assert served.stats.released == 2


class TestEventSink:
    def test_monotonic_ordering_and_counter(self):
        reg = MetricsRegistry()
        sink = EventSink(registry=reg)
        e1 = sink.emit("fail", device=3)
        e2 = sink.emit("recover", device=3)
        assert e2.t_mono >= e1.t_mono
        assert e1.attr("device") == 3 and e1.attr("missing", 7) == 7
        assert len(sink) == 2
        snap = reg.snapshot()["counters"]
        assert snap["events_total{kind=fail}"] == 1.0

    def test_elastic_controller_uses_sink(self):
        from repro.train.elastic import ElasticController

        reg = MetricsRegistry()
        sink = EventSink(registry=reg)
        ctl = ElasticController(n_devices=4, model_degree=2, sink=sink)
        ctl.fail([1])
        ctl.recover([1])
        kinds = [e.kind for e in sink.events]
        assert kinds == ["elastic_fail", "elastic_recover"]
        # the legacy 3-tuple event log keeps its shape, stamps now monotonic
        (k1, ids1, t1), (k2, ids2, t2) = ctl.events
        assert (k1, ids1) == ("fail", (1,))
        assert (k2, ids2) == ("recover", (1,))
        assert t2 >= t1


class TestTimingLint:
    def test_src_is_clean(self):
        out = subprocess.run(
            [sys.executable, os.path.join(REPO, "tools",
                                          "check_timing_lint.py")],
            capture_output=True, text=True)
        assert out.returncode == 0, out.stdout + out.stderr

    def test_lint_catches_raw_time(self, tmp_path):
        """The lint actually rejects what it claims to (guard against the
        patterns rotting as the tree moves)."""
        import importlib.util

        spec = importlib.util.spec_from_file_location(
            "check_timing_lint",
            os.path.join(REPO, "tools", "check_timing_lint.py"))
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        check = mod.check

        bad = tmp_path / "bad.py"
        bad.write_text("import time\nx = time.time()\n"
                       "y = 1  # time.time() in a comment is fine\n")
        hits = check(bad)
        assert [lineno for lineno, _ in hits] == [1, 2]
